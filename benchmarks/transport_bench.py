"""Pickle-vs-shm transport roundtrip and the measured crossover (PR 10).

The measured roundtrip is the process plane's heaviest path: a cross-child
``sync_weights`` relay — source child exports its params, the parent
forwards them to the destination child, the destination stores them and
acks. Two stub worker-process pairs run the IDENTICAL protocol
(``sync_export`` → ``store_params`` → release), differing only in
transport: the pickle pair (``shm=False``) hauls every byte through the
pipe twice (reply + relayed request, each pickled, chunked through the
kernel, unpickled), while the shm pair writes the bytes once into the
source child's pooled segment and relays 100-byte descriptors — the
destination copies straight out of the mapped views. Param trees are
cached child-side per size, so timed reps measure the transport, not
``np.arange``.

Rows:

- ``transport/{pickle,shm}_roundtrip_ms_{1,16,64,256}mib`` — one-host
  relay roundtrip per payload size (min over reps; the derived column on
  shm rows shows the speedup)
- ``transport/crossover_kib`` — smallest swept payload where the shm path
  beats pickle; ``shm_transport.DEFAULT_THRESHOLD`` is set from this
  measurement (with headroom for descriptor/ack overhead on trees of many
  small arrays)
"""
from __future__ import annotations

import time

SIZES_MIB = (1, 16, 64, 256)
SWEEP_KIB = (8, 16, 32, 64, 128, 256, 512, 1024)
STUB = "repro.launch.stub_wpg:make_busy_wpg"


class _Pair:
    """Source + destination worker process sharing a transport mode, with
    one deployment per payload size on each side."""

    def __init__(self, base_gid: int, shm: bool):
        from repro.launch.proc_plane import GroupProcess
        self.src = GroupProcess(base_gid, wpg_factory=STUB, shm=shm,
                                shm_threshold=1 << 10,
                                node_id=f"tbench-src{base_gid}")
        self.dst = GroupProcess(base_gid + 1, wpg_factory=STUB, shm=shm,
                                shm_threshold=1 << 10,
                                node_id=f"tbench-dst{base_gid}")
        self._deps = {}

    def _dep_for(self, kib: int) -> str:
        dep = self._deps.get(kib)
        if dep is None:
            from repro.core import api
            dep = f"d{kib}"
            for gp in (self.src, self.dst):
                gp.create_deployment(api.DeploymentSpec(
                    deployment_id=dep, job_id="bench", model_name="stub",
                    role="train", overrides=(("sync_kib", kib),)))
            self._deps[kib] = dep
        return dep

    def sync_roundtrip_ms(self, kib: int, reps: int) -> float:
        """One cross-child weight sync, exactly as WPGProxy relays it."""
        from repro.launch import shm_transport as shmt
        dep = self._dep_for(kib)
        best = float("inf")
        for i in range(reps + 1):           # +1 warm: arange + segment alloc
            t0 = time.perf_counter()
            tree, _ = self.src.call("sync_export", {"dep": dep},
                                    decode_reply=False)
            segs = shmt.refs_in(tree)
            try:
                self.dst.call("store_params", {"dep": dep, "tree": tree})
            finally:
                self.src.release_segments(segs)
            dt = time.perf_counter() - t0
            if i > 0:
                best = min(best, dt)
        # the landed params must checksum: this is a transfer, not a timer
        n = (kib << 10) // 4
        got, _ = self.dst.call("execute", {
            "dep": dep, "req_id": 0, "job_id": "bench", "op": "forward",
            "args": (), "kwargs": {"stored_sum": True}})
        assert got["stored_sum"] == float(n * (n - 1) // 2), kib
        return best * 1e3

    def close(self):
        self.src.shutdown()
        self.dst.shutdown()


def run():
    from repro.launch import shm_transport as shmt

    if not shmt.shm_available():
        return [("transport/shm_available", 0, "no shm: bench skipped")]

    pkl = _Pair(90, shm=False)
    shm = _Pair(92, shm=True)
    rows = [("transport/shm_available", 1, "")]
    try:
        for mib in SIZES_MIB:
            reps = 3 if mib >= 64 else 6
            t_pkl = pkl.sync_roundtrip_ms(mib << 10, reps)
            t_shm = shm.sync_roundtrip_ms(mib << 10, reps)
            rows.append((f"transport/pickle_roundtrip_ms_{mib}mib",
                         round(t_pkl, 3), f"{mib} MiB sync relay, pipe"))
            rows.append((f"transport/shm_roundtrip_ms_{mib}mib",
                         round(t_shm, 3),
                         f"{t_pkl / t_shm:.1f}x vs pickle"))
        crossover = None
        for kib in SWEEP_KIB:
            t_pkl = pkl.sync_roundtrip_ms(kib, 12)
            t_shm = shm.sync_roundtrip_ms(kib, 12)
            if crossover is None and t_shm < t_pkl:
                crossover = kib
        rows.append(("transport/crossover_kib",
                     -1 if crossover is None else crossover,
                     f"DEFAULT_THRESHOLD={shmt.DEFAULT_THRESHOLD >> 10} KiB"))
    finally:
        pkl.close()
        shm.close()
    return rows


if __name__ == "__main__":
    for name, value, derived in run():
        print(f"{name},{value},{derived}")
