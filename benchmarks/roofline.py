"""Roofline analysis (deliverable g): combine the dry-run's compiled-HLO
measurements with an analytic TPU-execution model into the three roofline
terms per (arch x shape x mesh).

Methodology (documented in EXPERIMENTS.md §Roofline):
- compute term    = max(HLO_flops, analytic_flops) / peak. The HLO count is
  trip-weighted (repro.launch.hlo_cost) and captures replication waste; the
  analytic floor covers decode cells where XLA:CPU strength-reduces GEMV
  dots out of existence.
- memory term     = analytic HBM traffic / bw. The compiled-HLO traffic is
  reported as reference but reflects XLA:CPU's fusion (far less aggressive
  than TPU) and would overstate TPU HBM traffic by ~an order of magnitude.
  The analytic model assumes flash/SSD kernels keep score matrices in VMEM.
- collective term = HLO collective bytes (trip-weighted, per device), with
  ring factors: all-reduce 2x, others 1x.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HW
from repro.models.registry import build_model

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


# ----------------------------------------------------------- analytic flops
def _attn_layers(cfg, seq: int, kind: str):
    """[(n_layers, context_len, q_len_factor)] attention context terms."""
    out = []
    s = seq
    if cfg.family in ("dense", "moe"):
        if cfg.local_global_period:
            n_global = cfg.num_layers // cfg.local_global_period
            n_local = cfg.num_layers - n_global
            out.append((n_global, s / 2 if kind != "decode" else s, 1.0))
            w = min(cfg.sliding_window, s)
            out.append((n_local, w / 2 if kind != "decode" else w, 1.0))
        else:
            out.append((cfg.num_layers, s / 2 if kind != "decode" else s, 1.0))
    elif cfg.family == "vlm":
        n_cross = cfg.num_layers // cfg.cross_attn_period
        out.append((cfg.num_layers - n_cross,
                    s / 2 if kind != "decode" else s, 1.0))
        out.append((n_cross, cfg.vision_seq, 1.0))
    elif cfg.family == "audio":
        out.append((cfg.num_layers, s / 2 if kind != "decode" else s, 1.0))
        out.append((cfg.num_layers, cfg.encoder_seq, 1.0))   # cross
        if kind != "decode":  # encoder runs on train/prefill
            out.append((cfg.encoder_layers, cfg.encoder_seq, 1.0))
    elif cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.attn_period
        out.append((n_attn, s / 2 if kind != "decode" else s, 1.0))
    return out


def analytic_flops(arch: str, shape_name: str) -> float:
    """Useful total FLOPs for one step of this cell (whole mesh)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    kind = shape.kind
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    mult = {"train": 6, "prefill": 2, "decode": 2}[kind]
    total = mult * model.active_param_count() * tokens

    # attention context terms: 4*T*H*hd flops/token/layer (QK^T + PV)
    attn_mult = {"train": 4, "prefill": 1, "decode": 1}[kind]
    hd = cfg.resolved_head_dim
    for n_layers, ctx, _ in _attn_layers(cfg, shape.seq_len, kind):
        total += (attn_mult * n_layers * 4 * ctx * cfg.num_heads * hd) * tokens

    # SSD terms: ~2*chunk*(n+p) flops/token/head/layer intra-chunk
    if cfg.family in ("ssm", "hybrid"):
        n_mamba = cfg.num_layers
        if cfg.family == "hybrid":
            n_mamba -= cfg.num_layers // cfg.attn_period
        if kind == "decode":
            per_tok = 4 * cfg.ssm_state * cfg.ssm_head_dim   # state update+read
        else:
            per_tok = 2 * cfg.ssm_chunk * (cfg.ssm_state + cfg.ssm_head_dim)
        total += (attn_mult * n_mamba * per_tok * cfg.ssm_nheads) * tokens
    return total


# --------------------------------------------------------- analytic memory
def _shard_counts(rules: str, n_chips: int):
    """(param shards, moment shards, data shards) under the rule set."""
    model_axis = 16
    data_axes = n_chips // model_axis
    if rules in ("fsdp_tp", "long"):
        return n_chips, n_chips, data_axes
    return model_axis, n_chips, data_axes     # tp: params TP-only; ZeRO moments


def analytic_memory_bytes(arch: str, shape_name: str, rules: str,
                          n_chips: int, grad_accum: int = 1) -> float:
    """Per-device HBM traffic for one step, assuming TPU-fused kernels
    (flash attention / fused SSD: score matrices never round-trip HBM)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    n = model.param_count()
    kind = shape.kind
    pshard, zshard, dshard = _shard_counts(rules, n_chips)
    p_dev = 2.0 * n / pshard

    if kind == "train":
        # params: fwd read + remat read + bwd read + write; fsdp re-gathers
        # per microbatch
        traffic = p_dev * (3 * grad_accum + 1)
        traffic += (8.0 * n / zshard) * 2 * 2          # mu+nu read+write f32
        traffic += (4.0 * n / zshard) * 2 * grad_accum  # grad accum rw f32
    elif kind == "prefill":
        traffic = p_dev
    else:
        traffic = p_dev                                 # one full param read
    # activations: residual stream IO per layer (read+write a handful of
    # times: norms, proj in/out, residual adds) — c ~= 10 for train (incl.
    # remat re-reads), 4 otherwise
    tokens_local = shape.global_batch * (shape.seq_len if kind != "decode"
                                         else 1) / dshard
    c = 10 if kind == "train" else 4
    layers = cfg.num_layers + cfg.encoder_layers
    traffic += layers * tokens_local * cfg.d_model * 2.0 * c
    # KV cache traffic
    if kind != "train" and cfg.num_heads:
        kvb = (2 * cfg.num_layers * shape.global_batch * shape.seq_len
               * cfg.num_kv_heads * cfg.resolved_head_dim * 2.0)
        kvb /= n_chips  # cache sharded over (batch x kv-or-seq)
        traffic += kvb  # prefill: write; decode: read
    if cfg.family in ("ssm", "hybrid") and kind == "decode":
        state = (cfg.num_layers * shape.global_batch * cfg.ssm_nheads
                 * cfg.ssm_head_dim * cfg.ssm_state * 4.0) / max(dshard, 1)
        traffic += 2 * state
    return traffic


# ------------------------------------------------------------------ report
def load_cells(mesh_dir: str) -> List[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, mesh_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def note_for(dominant: str, useful: float) -> str:
    if dominant == "collective":
        return ("sequence-parallel TP (reduce-scatter/all-gather in bf16) "
                "would cut the per-layer activation all-reduces")
    if dominant == "memory":
        return ("raise arithmetic intensity: bigger microbatch per device / "
                "fuse optimizer update; params+moments traffic dominates")
    if useful < 0.3:
        return ("compute-bound but replicated: pad heads to a mesh multiple "
                "so attention shards over the model axis")
    return "near roofline: compute-bound with useful work dominating"


def analyze_cell(cell: dict) -> Optional[dict]:
    if cell.get("status") != "OK":
        return None
    arch, shape_name = cell["arch"], cell["shape"]
    n_chips = cell["n_chips"]
    rl = cell["roofline"]
    a_flops = analytic_flops(arch, shape_name)
    a_flops_dev = a_flops / n_chips
    hlo_flops_dev = rl["hlo_flops_per_device"]
    flops_dev = max(hlo_flops_dev, a_flops_dev)
    mem_dev = analytic_memory_bytes(arch, shape_name,
                                    cell.get("rules", "tp"), n_chips,
                                    cell.get("grad_accum", 1))
    coll = cell["collectives"]["bytes_by_kind"]
    coll_dev = (2.0 * coll.get("all-reduce", 0.0)
                + coll.get("all-gather", 0.0)
                + coll.get("reduce-scatter", 0.0)
                + coll.get("all-to-all", 0.0)
                + coll.get("collective-permute", 0.0))
    compute_s = flops_dev / HW["peak_flops_bf16"]
    memory_s = mem_dev / HW["hbm_bw"]
    collective_s = coll_dev / HW["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    model_flops = rl["model_flops_total"]
    useful = model_flops / max(flops_dev * n_chips, 1e-9)
    # roofline fraction: useful work at peak over the modelled step time
    frac = (model_flops / n_chips / HW["peak_flops_bf16"]) / max(bound, 1e-12)
    return {
        "arch": arch, "shape": shape_name, "mesh": cell["mesh"],
        "rules": cell.get("rules"), "grad_accum": cell.get("grad_accum", 1),
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_total": hlo_flops_dev * n_chips,
        "useful_ratio": useful, "roofline_fraction": frac,
        "hlo_traffic_ref_bytes": rl["hlo_bytes_per_device"],
        "note": note_for(dominant, useful),
    }


def markdown_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | rules | compute s | memory s | collective s | "
           "dominant | useful % | roofline % |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['rules']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant']} "
            f"| {100 * r['useful_ratio']:.1f} "
            f"| {100 * r['roofline_fraction']:.1f} |")
    return hdr + "\n".join(lines)


def run() -> list[tuple[str, float, str]]:
    rows_out = []
    table_rows = []
    for cell in load_cells("pod_16x16"):
        r = analyze_cell(cell)
        if r is None:
            rows_out.append((f"roofline/{cell['arch']}/{cell['shape']}",
                             0.0, cell.get("reason", cell.get("status"))))
            continue
        table_rows.append(r)
        rows_out.append(
            (f"roofline/{r['arch']}/{r['shape']}/fraction",
             r["roofline_fraction"],
             f"dom={r['dominant']} useful={100*r['useful_ratio']:.0f}%"))
    os.makedirs(os.path.join(ART, ".."), exist_ok=True)
    with open(os.path.join(ART, "..", "roofline_pod.json"), "w") as f:
        json.dump(table_rows, f, indent=1)
    with open(os.path.join(ART, "..", "roofline_pod.md"), "w") as f:
        f.write(markdown_table(table_rows))
    return rows_out


if __name__ == "__main__":
    for r in run():
        print(r)
