"""Scheduler microbenchmarks: HRRS vs FCFS on mixed queues, the §5.2
data-structure costs (segment-tree gang check, interval-set fitting) in
microseconds per call, deep-queue per-admission cost of the incremental
admission index vs Algorithm 1's full re-score, the dispatch plane's
concurrency gain + per-op control overhead (serial driver vs
Router.run_until_idle), the serve-mode submit->admission latency on an idle
persistent plane, the control plane's placement costs: cold/warm fit
decision latency vs resident-job count, the wall-clock of a realized
repack migration (hold -> drain -> StateManager.migrate -> rehome), and
the process plane's costs: IPC dispatch round-trip through a group worker
process vs the in-process call, and 2-group compute-bound overlap in both
dispatch modes (threads GIL-bound near 1.0x serialized; processes overlap
wherever cores exist).
"""
from __future__ import annotations

import gc
import time

import numpy as np

from repro.core import api
from repro.core.router import Router
from repro.core.scheduler import hrrs
from repro.core.scheduler.executor import TaskExecutor, VirtualClock
from repro.core.scheduler.intervals import IntervalSet
from repro.core.scheduler.placement import (NodeGroup, PlacementConfig,
                                            PlacementPolicy)
from repro.core.scheduler.repack_index import RepackIndex
from repro.core.scheduler.ring import CapacityRing
from repro.core.traces import synthetic_job_mix


class _SleepWPG:
    """Stub execution backend: sleep releases the GIL, so cross-group
    overlap through the concurrent dispatch plane is real."""

    def __init__(self, spec, sm, duration: float):
        self.spec = spec
        self.sm = sm
        self.exec_log = []
        self._duration = duration

    @property
    def job_prefix(self):
        return f"{self.spec.job_id}:{self.spec.deployment_id}"

    def resident(self):
        return False

    def ensure_resident(self):
        return 0.0

    def offload(self, to=None):
        return 0.0

    def execute(self, qop):
        if self._duration:
            time.sleep(self._duration)
        self.exec_log.append((qop.op.value, self._duration))
        return None


def _stub_router(n_groups: int, duration: float) -> tuple:
    router = Router(wpg_factory=lambda spec, sm: _SleepWPG(spec, sm,
                                                           duration))
    specs = []
    for g in range(n_groups):
        spec = api.DeploymentSpec(deployment_id=f"dep{g}", job_id=f"job{g}",
                                  model_name="stub", role="train")
        router.create_deployment(spec, group_id=g)
        specs.append(spec)
    return router, specs


def _dispatch_wall(n_groups: int, ops_per_group: int, duration: float,
                   concurrent: bool) -> float:
    router, specs = _stub_router(n_groups, duration)
    for spec in specs:
        for i in range(ops_per_group):
            router.submit_queued_operation(
                api.make_op(spec, api.Op.FORWARD, i))
    t0 = time.perf_counter()
    if concurrent:
        router.run_until_idle(timeout=60.0)
    else:
        router.drain()
    return time.perf_counter() - t0


def _proc_roundtrip_us(iters: int = 200) -> float:
    """IPC dispatch overhead of the process plane: one zero-cost op through
    ``WPGProxy.execute`` — payload pickle, pipe write, child dispatch,
    reply pickle, log-mirror append — measured directly against the proxy
    (no admission path), the apples-to-apples counterpart of the in-process
    ``dispatch/op_overhead_us`` row (~65 us)."""
    router = Router(process_plane=True,
                    proc_wpg_factory="repro.launch.stub_wpg:make_busy_wpg")
    spec = api.DeploymentSpec(deployment_id="dep0", job_id="job0",
                              model_name="stub", role="train")
    try:
        wpg = router.create_deployment(spec, group_id=0)
        qop = api.make_op(spec, api.Op.FORWARD, 0)
        wpg.execute(qop)                       # warm: spawn + handshake
        return _time_us(lambda: wpg.execute(qop), iters=iters)
    finally:
        router.close_processes()


def _compute_overlap_wall(n_groups: int, ops_per_group: int, busy_s: float,
                          process_plane: bool) -> float:
    """Wall-clock of a COMPUTE-BOUND 2-group workload (GIL-holding spin per
    op, burning thread CPU time) in either dispatch mode. Children are
    warmed with one zero-cost op each so spawn/handshake stays outside the
    timed region."""
    if process_plane:
        router = Router(process_plane=True,
                        proc_wpg_factory="repro.launch.stub_wpg:make_busy_wpg")
    else:
        from repro.launch.stub_wpg import make_busy_wpg
        router = Router(wpg_factory=make_busy_wpg)
    try:
        specs = []
        for g in range(n_groups):
            spec = api.DeploymentSpec(deployment_id=f"dep{g}",
                                      job_id=f"job{g}", model_name="stub",
                                      role="train")
            router.create_deployment(spec, group_id=g)
            specs.append(spec)
        for spec in specs:
            router.submit_queued_operation(api.make_op(spec, api.Op.FORWARD, 0))
        router.run_until_idle(timeout=60.0)
        t0 = time.perf_counter()
        for spec in specs:
            for i in range(ops_per_group):
                router.submit_queued_operation(
                    api.make_op(spec, api.Op.FORWARD, i, busy_s=busy_s))
        router.run_until_idle(timeout=60.0)
        return time.perf_counter() - t0
    finally:
        if process_plane:
            router.close_processes()


def _serve_attach_latency_us(iters: int = 300) -> float:
    """submit -> admission latency on an IDLE serving plane: the parked
    worker must wake on the submit notification and start the op. Measured
    per op as ``t_started - t_submit`` (both on time.monotonic, the router's
    clock), median over ``iters`` one-at-a-time submissions so each lands on
    a fully idle plane."""
    router, specs = _stub_router(1, 0.0)
    lat = []
    with router:                      # serve() ... shutdown()
        for i in range(iters):
            qop = api.make_op(specs[0], api.Op.FORWARD, i)
            t0 = time.monotonic()
            fut = router.submit_queued_operation(qop)
            fut.wait(timeout=10.0)
            lat.append(router.executor.tasks[qop.req_id].t_started - t0)
            router.wait_idle(timeout=10.0)
    return float(np.median(lat) * 1e6)


def _mixed_queue(n: int, seed: int = 0, equal_exec: bool = False):
    rng = np.random.default_rng(seed)
    return [hrrs.Request(req_id=i, job_id=f"job{rng.integers(0, 4)}",
                         op="update_actor",
                         exec_time=30.0 if equal_exec
                         else float(rng.uniform(5, 60)),
                         arrival_time=float(rng.uniform(0, 100)))
            for i in range(n)]


def _time_us(fn, iters=200, repeats=4) -> float:
    """Mean per-call latency over the best of ``repeats`` timing chunks,
    with the cyclic GC paused inside the timed region.

    Best-of-repeats is the ``timeit`` recommendation: interference (VM
    steal, frequency scaling, another bench row's leftover heap) only ever
    ADDS time, so the minimum chunk is the closest estimate of the true
    cost. GC pauses otherwise charge whichever row happens to trip a
    collection for garbage produced by earlier rows."""
    per_chunk = max(1, iters // repeats)
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            for _ in range(per_chunk):
                fn()
            best = min(best, (time.perf_counter() - t0) / per_chunk)
    finally:
        if was_enabled:
            gc.enable()
    return best * 1e6


def _admission_us(n_queued: int, n_jobs: int, use_index: bool,
                  seed: int = 0, mixed_priority: bool = False) -> float:
    """Per-admission cost of ``n_queued`` ops through the executor's
    submit + pick/start/finish cycle on one group: the dispatch plane's hot
    path. Submissions are INSIDE the timed region so the indexed path is
    charged for its O(log n) insert maintenance, not just the pick.
    ``mixed_priority`` assigns each job a distinct tenant priority weight,
    exercising the kinetic tournament's extra crossing class — the flat-cost
    claim must survive the multi-tenant score term."""
    clock = VirtualClock()
    ex = TaskExecutor(now=clock, policy="hrrs",
                      use_admission_index=use_index)
    rng = np.random.default_rng(seed)
    prio_of = {f"job{j}": (0.5, 1.0, 2.0, 4.0)[j % 4]
               for j in range(n_jobs)} if mixed_priority else {}
    reqs = [hrrs.Request(req_id=i + 1, job_id=f"job{i % n_jobs}",
                         op="update_actor",
                         exec_time=float(rng.uniform(0.5, 8.0)),
                         arrival_time=0.0,
                         priority=prio_of.get(f"job{i % n_jobs}", 1.0))
            for i in range(n_queued)]
    gaps = [float(rng.uniform(0.0, 0.2)) for _ in range(n_queued)]
    admitted = 0
    t0 = time.perf_counter()
    for r, gap in zip(reqs, gaps):
        r.arrival_time = clock.now()
        ex.submit(r, group_id=0)
        clock.advance(gap)
    while True:
        task = ex.pick_next(0)
        if task is None:
            break
        ex.try_start(task)
        ex.finish(task)
        clock.advance(0.05)
        admitted += 1
    dt = time.perf_counter() - t0
    assert admitted == n_queued
    return dt / n_queued * 1e6


def _placement_decision_us(n_resident: int, seed: int = 0) -> tuple:
    """Cold + warm fit latency against a fleet already hosting
    ``n_resident`` placed jobs (the §4.3.2 decision hot path)."""
    horizon = 28_800.0
    n_groups = max(4, n_resident // 4)
    pol = PlacementPolicy(
        [NodeGroup(g, 8, IntervalSet([(0.0, horizon)]))
         for g in range(n_groups)],
        PlacementConfig(horizon=horizon))
    profiles = synthetic_job_mix(n_resident + 1, seed=seed)
    for i, p in enumerate(profiles[:-1]):
        pol.place_warm(f"res{i}", p.mean_trace())
    probe = profiles[-1].mean_trace()
    # one spare empty group so the cold probe always has a clean target
    pol.add_group(NodeGroup(n_groups, 8, IntervalSet([(0.0, horizon)])))

    def warm_probe():
        assert pol.place_warm("probe", probe) is not None
        pol.remove("probe")

    def cold_probe():
        assert pol.place_cold("probe", 1, 600.0) is not None
        pol.remove("probe")

    return _time_us(cold_probe, iters=50), _time_us(warm_probe, iters=20)


def _repack_plan_us(n_resident: int, seed: int = 0) -> float:
    """Latency of ONE incremental repack planning pass
    (``PlacementPolicy.plan_repack``) against a fleet hosting
    ``n_resident`` placed jobs: the reconciler's periodic decision cost
    (clone + per-job re-fit + interference deltas; no mutation)."""
    horizon = 28_800.0
    n_groups = max(4, n_resident // 4)
    pol = PlacementPolicy(
        [NodeGroup(g, 8, IntervalSet([(0.0, horizon)]))
         for g in range(n_groups)],
        PlacementConfig(horizon=horizon))
    profiles = synthetic_job_mix(n_resident, seed=seed)
    for i, p in enumerate(profiles):
        pol.place_warm(f"res{i}", p.mean_trace())
    iters = max(2, 64 // max(n_resident, 1))
    return _time_us(lambda: pol.plan_repack(origin=0.0, min_gain=0.001),
                    iters=iters)


def _repack_plan_inc_us(n_resident: int, seed: int = 0,
                        dirty_groups: int = 2, iters: int = 40) -> float:
    """Steady-state latency of one INCREMENTAL repack planning pass
    (``RepackIndex.plan``) against a fleet hosting ``n_resident`` placed
    jobs. Per pass, ``dirty_groups`` groups are flagged as drifted (the
    reconciler's occupancy-drift trigger); candidates come from those
    groups only and destination search is bound-pruned and capped exactly
    as ``DirectorConfig`` defaults configure the shipped reconcile path.
    The full ``plan_repack`` oracle re-fits every job on a policy clone
    instead — O(jobs x groups) per pass."""
    horizon = 28_800.0
    n_groups = max(4, n_resident // 4)
    pol = PlacementPolicy(
        [NodeGroup(g, 8, IntervalSet([(0.0, horizon)]))
         for g in range(n_groups)],
        PlacementConfig(horizon=horizon))
    profiles = synthetic_job_mix(n_resident, seed=seed)
    for i, p in enumerate(profiles):
        pol.place_warm(f"res{i}", p.mean_trace())
    idx = RepackIndex(pol)
    # converge first: drain the move backlog the initial placement leaves
    # behind so the timed passes measure steady-state drift response, not
    # a cold start (the first pass sees every group dirty)
    for _ in range(4):
        plan = idx.plan(origin=0.0, min_gain=0.001, max_dest_search=12)
        if not plan.deltas:
            break
        pol.apply_repack(plan)
    gids = sorted(g.group_id for g in pol.groups)
    cursor = [0]

    def drift_pass():
        for k in range(dirty_groups):
            idx.mark_dirty(gids[(cursor[0] + k) % len(gids)])
        cursor[0] += dirty_groups
        idx.plan(origin=0.0, min_gain=0.001, max_dest_search=12)

    drift_pass()     # warm the per-group summary cache (steady state)
    return _time_us(drift_pass, iters=iters)


def _repack_migrate_s(nbytes: int = 8 << 20) -> float:
    """Wall-clock of ONE realized repack migration through
    ``Router.reassign_job``: admission hold, in-flight drain,
    StateManager.migrate of ~nbytes of managed state, queued-op rehome,
    release. Queued ops survive and complete on the destination group."""
    router, specs = _stub_router(2, 0.0)
    spec = specs[0]
    wpg = router.wpgs[spec.deployment_id]
    sm = router.state_managers[0]
    n_arrays = 8
    arr = np.ones((nbytes // n_arrays // 4,), np.float32)
    for i in range(n_arrays):
        sm.register(wpg.job_prefix, {f"w{i}": arr})
    queued = [router.submit_queued_operation(
        api.make_op(spec, api.Op.FORWARD, i)) for i in range(16)]
    t0 = time.perf_counter()
    router.reassign_job(spec.job_id, 1)
    dt = time.perf_counter() - t0
    router.drain()
    for f in queued:
        f.result()
    return dt


def run() -> list[tuple[str, float, str]]:
    rows = []
    # HRRS vs FCFS: switches on a comparable-service-time queue — the regime
    # where HRRS's switch-amortising guarantee is unconditional (§4.4; with
    # wildly unequal exec times HRRN's shortest-first pressure can trade a
    # switch for responsiveness)
    q = _mixed_queue(64, seed=2, equal_exec=True)
    plan_h = hrrs.schedule(None, None, [hrrs.Request(**vars(r)) for r in q],
                           100.0, None, t_load=10.0, t_offload=10.0)
    plan_f = hrrs.fcfs_schedule(None, None, [hrrs.Request(**vars(r)) for r in q],
                                100.0, None, t_load=10.0, t_offload=10.0)
    rows.append(("hrrs/switches", hrrs.total_switches(plan_h),
                 f"fcfs={hrrs.total_switches(plan_f)}"))
    rows.append(("hrrs/makespan_s", hrrs.makespan(plan_h),
                 f"fcfs={hrrs.makespan(plan_f):.0f}"))
    assert hrrs.total_switches(plan_h) <= hrrs.total_switches(plan_f)
    # heterogeneous queue: report both (no ordering guarantee)
    q2 = _mixed_queue(64, seed=3)
    plan_h2 = hrrs.schedule(None, None, [hrrs.Request(**vars(r)) for r in q2],
                            100.0, None, t_load=10.0, t_offload=10.0)
    plan_f2 = hrrs.fcfs_schedule(None, None,
                                 [hrrs.Request(**vars(r)) for r in q2],
                                 100.0, None, t_load=10.0, t_offload=10.0)
    rows.append(("hrrs/switches_hetero", hrrs.total_switches(plan_h2),
                 f"fcfs={hrrs.total_switches(plan_f2)}"))

    # scheduling-call latency
    us = _time_us(lambda: hrrs.schedule(
        None, None, [hrrs.Request(**vars(r)) for r in q], 100.0, None,
        10.0, 10.0), iters=50)
    rows.append(("hrrs/schedule_64req_us", us, ""))

    # §5.2.1 segment-tree gang-feasibility on the full 28 800-slot ring
    ring = CapacityRing(2048, slots=28_800)
    for i in range(64):
        ring.reserve(i * 400.0, 120.0, 16)
    us = _time_us(lambda: ring.feasible(7_000.0, 600.0, 64), iters=2_000)
    rows.append(("ring/gang_check_us", us, "O(log 28800)"))

    # interval-set simulate_insert (bisect fitting)
    iv = IntervalSet([(i * 100.0, i * 100.0 + 60.0) for i in range(200)])
    segs = [(5.0, 20.0), (130.0, 25.0), (410.0, 30.0)]
    us = _time_us(lambda: iv.simulate_insert(segs, shift=3.0), iters=5_000)
    rows.append(("intervals/simulate_insert_us", us, "O(N log M)"))

    # deep-queue admission: incremental index vs Algorithm 1 full re-score,
    # multiple jobs multiplexed per group (the §4.4 control-plane hot path)
    for n in (64, 256, 1024):
        full_us = _admission_us(n, n_jobs=4, use_index=False)
        idx_us = _admission_us(n, n_jobs=4, use_index=True)
        rows.append((f"admission/full_rescore_n{n}_us", full_us,
                     "per admission, 4 jobs/group"))
        rows.append((f"admission/indexed_n{n}_us", idx_us,
                     f"speedup={full_us / max(idx_us, 1e-9):.1f}x"))
    # deep-queue extension: the indexed path stays flat at 4096 (the full
    # re-score is omitted there — O(n^2) total, ~30 s for one row)
    rows.append(("admission/indexed_n4096_us",
                 _admission_us(4096, n_jobs=4, use_index=True),
                 "full re-score omitted at this depth"))
    # multi-tenant priority term: a mixed-priority pool (weights 0.5/1/2/4
    # across the job buckets) exercises the tournament's extra flat-level
    # crossing class; indexed admission must stay flat with the term on
    for n in (256, 1024):
        pf = _admission_us(n, n_jobs=4, use_index=False, mixed_priority=True)
        pi = _admission_us(n, n_jobs=4, use_index=True, mixed_priority=True)
        rows.append((f"admission/priority_full_n{n}_us", pf,
                     "mixed-priority pool, full re-score"))
        rows.append((f"admission/priority_indexed_n{n}_us", pi,
                     f"speedup={pf / max(pi, 1e-9):.1f}x"))

    # control plane: placement decision latency vs resident-job count, and
    # the wall-clock of a realized repack migration (8 MiB managed state)
    for n_res in (4, 16, 64):
        cold_us, warm_us = _placement_decision_us(n_res)
        rows.append((f"placement/decision_cold_n{n_res}_us", cold_us,
                     f"{n_res} resident jobs"))
        rows.append((f"placement/decision_warm_n{n_res}_us", warm_us,
                     "micro-shift fit + interference rank"))
    rows.append(("placement/repack_migrate_s", _repack_migrate_s(),
                 "hold+drain+migrate(8MiB)+rehome, 16 queued ops"))
    # reconciler: incremental repack PLANNING latency vs resident-job count
    # (plan-only — the realized moves are priced by repack_migrate_s above,
    # which also feeds the planner's migration-cost floor)
    for n_res in (4, 16, 64):
        rows.append((f"placement/repack_plan_n{n_res}_us",
                     _repack_plan_us(n_res),
                     f"full plan_repack over {n_res} resident jobs"))
    # fleet scale: the incremental RepackIndex (the shipped reconcile
    # path — dirty-group candidates, bound-pruned + capped destination
    # search) vs the full oracle; the full re-fit is O(jobs x groups) and
    # is omitted at n=1024 (tens of seconds for one row)
    full256 = _repack_plan_us(256)
    rows.append(("placement/repack_plan_full_n256_us", full256,
                 "full plan_repack, O(jobs x groups)"))
    inc256 = _repack_plan_inc_us(256)
    rows.append(("placement/repack_plan_inc_n256_us", inc256,
                 f"RepackIndex, speedup={full256 / max(inc256, 1e-9):.0f}x"))
    rows.append(("placement/repack_plan_n1024_us", _repack_plan_inc_us(1024),
                 "RepackIndex (shipped path); full re-fit omitted here"))

    # dispatch plane: cross-group overlap (4 groups x 6 x 10ms ops) and the
    # per-op control overhead of the concurrent driver on zero-cost ops
    w_serial = _dispatch_wall(4, 6, 0.01, concurrent=False)
    w_conc = _dispatch_wall(4, 6, 0.01, concurrent=True)
    rows.append(("dispatch/overlap_speedup", w_serial / max(w_conc, 1e-9),
                 f"serial={w_serial * 1e3:.0f}ms conc={w_conc * 1e3:.0f}ms"))
    n_ops = 200
    w0 = _dispatch_wall(1, n_ops, 0.0, concurrent=True)
    rows.append(("dispatch/op_overhead_us", w0 / n_ops * 1e6,
                 "run_until_idle, zero-cost ops"))
    # serve mode: submit -> admission latency against an idle persistent
    # plane (the parked worker's wakeup path, pinned so regressions show)
    rows.append(("dispatch/serve_attach_latency_us",
                 _serve_attach_latency_us(),
                 "median, idle serve() plane"))
    # process plane: IPC round-trip cost of one dispatched op (vs the
    # ~65us in-process op_overhead_us above), and the 2-group COMPUTE-bound
    # overlap in both modes — threads hold the GIL through the spin so they
    # serialize near 1.0x; worker processes overlap for real wherever >= 2
    # cores exist (the ratio is reported against the serialized cost)
    import os as _os
    rows.append(("dispatch/proc_roundtrip_us", _proc_roundtrip_us(),
                 "WPGProxy.execute, zero-cost op, vs in-process "
                 "op_overhead_us"))
    n_groups, ops, busy = 2, 3, 0.06
    serial_s = n_groups * ops * busy
    cores = len(_os.sched_getaffinity(0))
    w_thr = _compute_overlap_wall(n_groups, ops, busy, process_plane=False)
    w_proc = _compute_overlap_wall(n_groups, ops, busy, process_plane=True)
    rows.append(("dispatch/compute_overlap_threads_x",
                 w_thr / serial_s,
                 f"wall/serial, {n_groups}x{ops}x{busy * 1e3:.0f}ms spin, "
                 f"{cores} cores (GIL-bound ~1.0)"))
    rows.append(("dispatch/compute_overlap_procs_x",
                 w_proc / serial_s,
                 f"wall/serial, process plane, {cores} cores "
                 f"(<=0.6 with >=2 cores)"))
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.run import BENCH_JSON, write_bench_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=BENCH_JSON,
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()
    rows = run()
    for r in rows:
        print(r)
    if args.json:
        write_bench_json(rows, args.json)
        print(f"wrote {args.json} ({len(rows)} rows)")
