"""Fig. 7a/7b — GPU-hours per effective training step across deployment
regimes (colocated / split-async / PlexRL 2-job packing) at 7B/30B/235B.

Uses the measured cycle anatomy of Table 2 plus the paper's measured
switch costs (19.0 s optimizer-state load at 30B scale, scaled by model
bytes) and the Fig. 7c DP-efficiency ratios for colocated rollout
(52.74 % vs 75.03 % throughput-AUC).

GPU-hour accounting:
- colocated: the WHOLE pool is reserved for rollout+train serially; rollout
  is slowed by the oversized-DP efficiency ratio and every phase boundary
  pays the context-switch cost.
- split-async: rollout pool + train pool, overlapped; the slower side gates
  the step and the other side idles the difference (imbalance bubble).
- plexrl: rollout per-job; the train pool is time-sliced across two jobs, so
  each job is billed only its busy train time + its switch share.
"""
from __future__ import annotations

import numpy as np

from repro.core.traces import PAPER_TABLE2

# pool sizes (relative units) from Tab. 1 parallel settings
POOLS = {
    "7B": {"train": 8, "rollout": 2},
    "30B": {"train": 64, "rollout": 8},
    "235B": {"train": 96, "rollout": 32},
}
# paper-measured: optimizer load 19.0 s at 30B; scale ~ linearly with params
SWITCH_COST = {"7B": 19.0 * 7 / 30, "30B": 19.0, "235B": 19.0 * 235 / 30}
# Fig. 7c: colocated large-DP rollout achieves 52.74 % of the small-DP AUC
COLOC_ROLLOUT_EFF = 52.74 / 75.03


def regimes(size: str, n_packed: int = 2) -> dict[str, float]:
    e = PAPER_TABLE2[size]
    pool = POOLS[size]
    train_active = e["compute_log_prob"] + e["update_actor"] + e["sync_weight"]
    rollout = e["cycle"] - train_active           # rollout wall time (split)
    n_t, n_r = pool["train"], pool["rollout"]
    sw = SWITCH_COST[size]
    if size == "235B":
        # paper §6.2: ZeRO-offload (optimizer resident in host RAM) slashes
        # the 235B context-switch cost — model it at ~1/3
        sw = sw / 3.0

    # ---- colocated: whole pool serial; rollout slowed by oversized DP;
    # two mode switches per step (train->rollout->train)
    rollout_coloc = rollout * (n_r / n_t) / COLOC_ROLLOUT_EFF
    cycle_coloc = rollout_coloc + train_active + 2 * sw
    coloc = (n_t) * cycle_coloc

    # ---- split async: pools overlap; the longer side gates the step
    step = max(rollout, train_active)
    split_async = n_r * step + n_t * step

    # ---- plexrl (n-job packing): rollout per-job; the shared train pool's
    # reserved time is split across the packed jobs (unified provisioning,
    # §7.2). A step extends if the packed train demands oversubscribe the
    # rollout window.
    train_busy = train_active + 2 * sw
    step_plex = max(rollout, n_packed * train_busy)
    plexrl = n_r * step_plex + n_t * step_plex / n_packed

    return {"colocated": coloc, "split_async": split_async, "plexrl": plexrl,
            "saving_vs_split": 1.0 - plexrl / split_async}


def run() -> list[tuple[str, float, str]]:
    rows = []
    for size in ("7B", "30B", "235B"):
        r = regimes(size)
        for k in ("colocated", "split_async", "plexrl"):
            rows.append((f"fig7/{size}/{k}_gpu_s_per_step", r[k], ""))
        rows.append((f"fig7/{size}/saving_vs_split", r["saving_vs_split"],
                     "paper: 31.36%/30.10%/37.58%"))
    savings = [r[1] for r in rows if r[0].endswith("saving_vs_split")]
    # paper reports 30.10-37.58 % — assert we land in the band (the billing
    # convention leaves a few points of slack per size)
    assert all(0.20 < s < 0.50 for s in savings), savings
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
