"""Fig. 7c — decoding throughput per GPU: real-AUC / peak-AUC ratio under
small-DP (PlexRL) vs large-DP (colocated) rollout.

Same long-tail machinery as fig2 but reporting the paper's AUC metric for
the two DP settings used in the 235B experiment (DP_R=4 vs training-sized
DP). Paper: 75.03 % (PlexRL) vs 52.74 % (colocated).
"""
from __future__ import annotations

import numpy as np

from benchmarks.fig2_dp_mfu import rollout_mfu


def run() -> list[tuple[str, float, str]]:
    # sigma/sat calibrated to the paper's snapshot (235B, same steps):
    # sigma=0.4 response-length tail, replicas saturate at ~2 concurrent
    # sequences (235B decode is HBM-bound at tiny batch)
    small_dp = rollout_mfu(dp_size=4, n_samples=2048, sat_batch=2, seed=1,
                           sigma=0.4)
    large_dp = rollout_mfu(dp_size=48, n_samples=2048, sat_batch=2, seed=1,
                           sigma=0.4)
    rows = [
        ("fig7c/auc_ratio_small_dp", small_dp, "paper=0.7503"),
        ("fig7c/auc_ratio_large_dp", large_dp, "paper=0.5274"),
        ("fig7c/gap", small_dp - large_dp, "paper_gap=0.2229"),
    ]
    assert small_dp > large_dp, "small DP must be more saturated"
    assert abs(small_dp - 0.7503) < 0.05 and abs(large_dp - 0.5274) < 0.05
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
