"""Fig. 8 — CDF of normalised queueing delay + makespan across scheduling
policies (Isolated / Pack / Spread / Spread+Backfill) on a replayed job mix.

The job mix follows §6.3: Table-2-shaped RL tasks with agentic long-tail
rollout, strictly serial function invocations, trace-driven replay.
Artifacts (CDF points + makespans) are written to
benchmarks/artifacts/fig8.json for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core.simulator import run_policy_comparison
from repro.core.traces import synthetic_job_mix

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def run(n_jobs: int = 48, steps: int = 12, seed: int = 11
        ) -> list[tuple[str, float, str]]:
    profiles = synthetic_job_mix(n_jobs, seed=seed)
    res = run_policy_comparison(profiles, steps=steps,
                                arrival_rate=1 / 90.0, seed=seed,
                                total_nodes=32, group_size=8)
    rows = []
    art = {"policies": {}}
    iso_makespan = res["isolated"].makespan
    for pol, r in res.items():
        d = np.sort(r.norm_delays())
        art["policies"][pol] = {
            "delays": d.tolist(),
            "makespan": r.makespan,
            "utilization": r.utilization(),
        }
        rows.append((f"fig8/{pol}/p50_delay", float(np.percentile(d, 50)), ""))
        rows.append((f"fig8/{pol}/p95_delay", float(np.percentile(d, 95)), ""))
        rows.append((f"fig8/{pol}/makespan_vs_isolated",
                     r.makespan / iso_makespan,
                     "paper: spread_backfill=0.56"))
    # load sweep: the capacity gain depends on the offered load; the paper's
    # 1.8x sits inside this band
    for rate_s in (300.0, 150.0, 90.0, 45.0):
        r2 = run_policy_comparison(
            synthetic_job_mix(n_jobs, seed=seed + 1), steps=steps,
            arrival_rate=1 / rate_s, seed=seed + 1,
            total_nodes=32, group_size=8,
            policies=("isolated", "spread_backfill"))
        gain = r2["isolated"].makespan / r2["spread_backfill"].makespan
        rows.append((f"fig8/load_sweep/interarrival_{int(rate_s)}s/capacity_gain",
                     gain, "paper=1.8"))
        art.setdefault("load_sweep", {})[str(rate_s)] = gain
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, "fig8.json"), "w") as f:
        json.dump(art, f)
    # qualitative claims from the paper
    assert res["spread_backfill"].makespan <= res["isolated"].makespan
    assert (np.percentile(res["spread_backfill"].norm_delays(), 95)
            <= np.percentile(res["isolated"].norm_delays(), 95))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
