"""CI perf smoke: one steady-state incremental repack planning pass must
stay cheap at fleet scale.

The ceiling is deliberately generous (CI runners are slow and noisy —
locally the n=256 pass runs ~2 ms): this guards against the O(fleet)
regression class, e.g. someone re-introducing a full policy clone or a
per-pass re-fit of every job into the ``RepackIndex`` path, not against
constant-factor drift. Wired as a warn-only (``continue-on-error``) CI
step so a slow runner can never block a merge.

    PYTHONPATH=src python -m benchmarks.perf_smoke [--n 256] [--ceiling-ms 20]

Exit code 1 when the measured pass exceeds the ceiling.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.hrrs_bench import _repack_plan_inc_us


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256,
                    help="resident jobs in the synthetic fleet")
    ap.add_argument("--ceiling-ms", type=float, default=20.0,
                    help="warn threshold for one planning pass")
    args = ap.parse_args(argv)
    us = _repack_plan_inc_us(args.n, iters=20)
    ms = us / 1000.0
    verdict = "OK" if ms <= args.ceiling_ms else "SLOW"
    print(f"perf-smoke: repack_plan_inc n={args.n}: {ms:.2f} ms "
          f"(ceiling {args.ceiling_ms:.0f} ms) {verdict}")
    return 0 if ms <= args.ceiling_ms else 1


if __name__ == "__main__":
    sys.exit(main())
