"""CI perf smoke: two hot-path costs must stay cheap at fleet scale.

1. One steady-state incremental repack planning pass (``RepackIndex.plan``)
   against a synthetic fleet — guards the O(fleet) regression class, e.g.
   someone re-introducing a full policy clone or a per-pass re-fit of every
   job.
2. Per-admission cost through the indexed dispatch path with the
   multi-tenant priority term enabled (mixed-priority pool) — guards the
   flat-cost claim of the kinetic tournament: the tenant term adds one
   crossing class, not an O(n) re-score.

The ceilings are deliberately generous (CI runners are slow and noisy —
locally the n=256 repack pass runs ~2 ms and a priority-term admission
~20 us): they catch complexity-class regressions, not constant-factor
drift. Wired as a warn-only (``continue-on-error``) CI step so a slow
runner can never block a merge.

    PYTHONPATH=src python -m benchmarks.perf_smoke \
        [--n 256] [--ceiling-ms 20] [--admission-ceiling-us 300]

Exit code 1 when any measured cost exceeds its ceiling.
"""
from __future__ import annotations

import argparse
import sys

from benchmarks.hrrs_bench import _admission_us, _repack_plan_inc_us


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=256,
                    help="resident jobs / queued ops in the synthetic fleet")
    ap.add_argument("--ceiling-ms", type=float, default=20.0,
                    help="warn threshold for one repack planning pass")
    ap.add_argument("--admission-ceiling-us", type=float, default=300.0,
                    help="warn threshold for one indexed admission with the "
                         "tenant priority term enabled")
    args = ap.parse_args(argv)
    ok = True

    us = _repack_plan_inc_us(args.n, iters=20)
    ms = us / 1000.0
    verdict = "OK" if ms <= args.ceiling_ms else "SLOW"
    ok = ok and ms <= args.ceiling_ms
    print(f"perf-smoke: repack_plan_inc n={args.n}: {ms:.2f} ms "
          f"(ceiling {args.ceiling_ms:.0f} ms) {verdict}")

    adm_us = _admission_us(args.n, n_jobs=4, use_index=True,
                           mixed_priority=True)
    verdict = "OK" if adm_us <= args.admission_ceiling_us else "SLOW"
    ok = ok and adm_us <= args.admission_ceiling_us
    print(f"perf-smoke: priority_admission_indexed n={args.n}: "
          f"{adm_us:.1f} us (ceiling {args.admission_ceiling_us:.0f} us) "
          f"{verdict}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
