"""Cross-mesh migration cost (reshard included) at 2/4/8 devices per group.

The parent process's jax backend is already pinned to the default single
CPU device, so each measurement runs in a CHILD process launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=2*dpg`` (the flag must
be set before jax initialises — same trick as launch/dryrun.py and the CI
multi-device matrix leg). The child carves two disjoint ``dpg``-device
slices, registers ~8 MiB of model-sharded state on the source slice, and
times ``StateManager.migrate`` onto the destination slice: device_get off
the source mesh, device_put with the target slice's NamedShardings.

Rows complement ``placement/repack_migrate_s`` (hrrs_bench), which times a
same-mesh move through the full reassign_job path; these isolate the
cross-mesh reshard the PlacementDirector charges via its measured
``cross_min_gain`` floor.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

NBYTES = 8 << 20
DEVICES_PER_GROUP = (2, 4, 8)


def _child(dpg: int) -> None:
    import jax  # noqa: F401  (backend initialises under the forced flag)
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.state_manager import StateManager, Tier
    from repro.launch.mesh import DevicePlane

    plane = DevicePlane(slice_size=dpg)
    src = StateManager(node_id="src", mesh_slice=plane.slice_for_group(0))
    dst = StateManager(node_id="dst", mesh_slice=plane.slice_for_group(1))
    assert src.mesh_slice.devices != dst.mesh_slice.devices
    n_arrays = 8
    cols = dpg * 64
    rows_ = NBYTES // n_arrays // 4 // cols
    mesh = src.mesh_slice.mesh
    tree = {
        f"w{i}": jax.device_put(
            np.random.RandomState(i).rand(rows_, cols).astype(np.float32),
            NamedSharding(mesh, P(None, "model")))
        for i in range(n_arrays)}
    src.register("job:dep", tree, Tier.DEVICE, "params")
    # one warm-up migration (first device_put pays compilation/layout setup)
    src.migrate("job:dep", dst)
    dst.migrate("job:dep", src)
    t0 = time.perf_counter()
    moved = src.migrate("job:dep", dst)
    dt = time.perf_counter() - t0
    assert src.last_migrate["cross_mesh"]
    print(json.dumps({"dpg": dpg, "seconds": dt, "bytes": moved,
                      "n_devices": len(jax.devices())}))


def run() -> list:
    rows = []
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for dpg in DEVICES_PER_GROUP:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={2 * dpg}")
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(root, "src"), root,
                        env.get("PYTHONPATH")) if p)
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.mesh_bench",
             "--child", str(dpg)],
            env=env, cwd=root, capture_output=True, text=True, timeout=300)
        if proc.returncode != 0:
            raise RuntimeError(
                f"mesh_bench child dpg={dpg} failed: {proc.stderr[-2000:]}")
        data = json.loads(proc.stdout.strip().splitlines()[-1])
        rows.append((
            f"placement/cross_mesh_migrate_s_d{dpg}",
            round(data["seconds"], 6),
            f"reshard-included migrate(8MiB) across disjoint {dpg}-device "
            f"slices"))
    return rows


if __name__ == "__main__":
    if len(sys.argv) >= 3 and sys.argv[1] == "--child":
        _child(int(sys.argv[2]))
    else:
        for name, value, derived in run():
            print(f"{name},{value},{derived}")
