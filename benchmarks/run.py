"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV. Run:
    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    import benchmarks.fig2_dp_mfu as fig2
    import benchmarks.fig7_cost as fig7
    import benchmarks.fig7c_auc as fig7c
    import benchmarks.fig8_policies as fig8
    import benchmarks.table2_bubble as table2
    import benchmarks.hrrs_bench as hrrsb
    import benchmarks.roofline as roofline

    modules = [
        ("fig2_dp_mfu", fig2),
        ("fig7_cost", fig7),
        ("fig7c_auc", fig7c),
        ("fig8_policies", fig8),
        ("table2_bubble", table2),
        ("hrrs_bench", hrrsb),
        ("roofline", roofline),
    ]
    print("name,value,derived")
    failed = []
    for name, mod in modules:
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            print(f"{name}/ERROR,nan,{e!r}")
            continue
        for row_name, value, derived in rows:
            print(f"{row_name},{value},{derived}")
        print(f"{name}/elapsed_s,{time.time() - t0:.2f},")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
