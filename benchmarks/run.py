"""Benchmark harness: one module per paper table/figure.

Prints ``name,value,derived`` CSV and writes a machine-readable
``BENCH_<pr>.json`` (row name -> {value, units}) so the performance
trajectory is tracked across PRs. Run:

    PYTHONPATH=src python -m benchmarks.run [--json BENCH_PR10.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCH_JSON = "BENCH_PR10.json"


def write_bench_json(rows: list, path: str) -> None:
    """Persist bench rows as ``{name: {"value": ..., "units": ...}}``.
    ``units`` carries the human-readable derived/context column."""
    out = {}
    for name, value, derived in rows:
        try:
            value = float(value)
        except (TypeError, ValueError):
            value = str(value)
        out[name] = {"value": value, "units": str(derived)}
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")


def main() -> None:
    import benchmarks.fig2_dp_mfu as fig2
    import benchmarks.fig7_cost as fig7
    import benchmarks.fig7c_auc as fig7c
    import benchmarks.fig8_policies as fig8
    import benchmarks.table2_bubble as table2
    import benchmarks.hrrs_bench as hrrsb
    import benchmarks.mesh_bench as meshb
    import benchmarks.roofline as roofline
    import benchmarks.transport_bench as transportb

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=BENCH_JSON,
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args()

    modules = [
        ("fig2_dp_mfu", fig2),
        ("fig7_cost", fig7),
        ("fig7c_auc", fig7c),
        ("fig8_policies", fig8),
        ("table2_bubble", table2),
        ("hrrs_bench", hrrsb),
        ("mesh_bench", meshb),
        ("roofline", roofline),
        ("transport_bench", transportb),
    ]
    print("name,value,derived")
    failed = []
    all_rows = []
    for name, mod in modules:
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            failed.append((name, repr(e)))
            print(f"{name}/ERROR,nan,{e!r}")
            continue
        for row_name, value, derived in rows:
            print(f"{row_name},{value},{derived}")
        all_rows.extend(rows)
        elapsed = ((f"{name}/elapsed_s", round(time.time() - t0, 2), ""))
        print(f"{elapsed[0]},{elapsed[1]},")
        all_rows.append(elapsed)
    if args.json:
        write_bench_json(all_rows, args.json)
        print(f"wrote {args.json} ({len(all_rows)} rows)", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
