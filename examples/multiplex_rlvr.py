"""Two RLVR jobs multiplexed on one shared pool — the paper's core claim,
executed for real on this machine.

Runs the same two jobs twice:
  (a) isolated   — jobs run back-to-back on the pool (job-local reservation)
  (b) multiplexed— PlexRL interleaves them with HRRS + StateManager swaps

and compares wall-clock + billed GPU-seconds per step. Because each job's
rollout phase leaves the "training pool" idle, multiplexing reclaims those
bubbles (paper Fig. 7: up to 37.58 % GPU-hour reduction at scale).

Run:  PYTHONPATH=src python examples/multiplex_rlvr.py
"""
import time

import numpy as np

from repro.core.cluster import PlexCluster
from repro.core.controller import JobConfig

TINY = (("num_layers", 2), ("d_model", 48), ("num_heads", 4),
        ("num_kv_heads", 2), ("head_dim", 12), ("d_ff", 96),
        ("vocab_size", 64), ("tie_embeddings", True), ("attn_q_chunk", 32))


def make_jobs():
    return [
        JobConfig(job_id="alpha", model_name="qwen2-0.5b", steps=3,
                  batch_size=8, group_size=4, max_new_tokens=6, seq_len=32,
                  overrides=TINY, seed=1),
        JobConfig(job_id="beta", model_name="qwen2-0.5b", steps=3,
                  batch_size=8, group_size=4, max_new_tokens=6, seq_len=32,
                  overrides=TINY, seed=2),
    ]


def run(interleave: bool):
    cluster = PlexCluster(n_groups=1)
    for cfg in make_jobs():
        cluster.add_job(cfg)
    t0 = time.time()
    billing = cluster.run(interleave=interleave)
    wall = time.time() - t0
    return cluster, billing, wall


def main():
    print("=== isolated (back-to-back) ===")
    c1, b1, w1 = run(interleave=False)
    print(f"wall {w1:.1f}s; switches={len(c1.router.switch_log)}")

    print("=== PlexRL multiplexed ===")
    c2, b2, w2 = run(interleave=True)
    print(f"wall {w2:.1f}s; switches={len(c2.router.switch_log)}")

    for job in ("alpha", "beta"):
        print(f"{job}: billed gpu_s/step isolated={b1[job].gpu_seconds_per_step():.2f} "
              f"multiplexed={b2[job].gpu_seconds_per_step():.2f} "
              f"(switch overhead {b2[job].switch_seconds:.3f}s)")
        r = c2.controllers[job].reward_log
        print(f"{job}: rewards {np.round(r, 3).tolist()}")
    print("\nNOTE: on one CPU there is no idle-bubble to reclaim (every op is"
          "\ncompute-bound), so the win here is the MECHANISM demonstration:"
          "\nHRRS-batched context switches, measured setup costs, per-job"
          "\nbilling. The capacity gain at cluster scale is quantified by"
          "\nbenchmarks/fig8_policies.py (1.8x) and fig7_cost.py (31-38 %).")


if __name__ == "__main__":
    main()
