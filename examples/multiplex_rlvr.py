"""Two RLVR jobs multiplexed on a shared pool — the paper's core claim,
executed for real on this machine.

Part 1 (one group, HRRS mechanism): the same two jobs run
  (a) isolated   — back-to-back on the pool (job-local reservation)
  (b) multiplexed— PlexRL interleaves them with HRRS + StateManager swaps
and compares wall-clock + billed GPU-seconds per step.

Part 2 (two groups, concurrent dispatch plane): the same two jobs, one per
node group, run
  (c) serial     — the serial driver executes every admitted op inline,
                   so job A's rollout blocks job B's training functions
  (d) concurrent — Router.run_until_idle dispatches each group on its own
                   worker thread; job A's rollout overlaps job B's
                   update_actor in measured wall-clock time (XLA releases
                   the GIL while executing, so the overlap is real even on
                   this CPU container).

Part 3 (serve mode, the paper's §4.1 regime): the plane runs PERSISTENTLY
(`PlexCluster.serve()`), each job self-drives on its own client thread
through the dataflow API (Deployment handles + `.then` chains — see
core/controller.py for the straight-line GRPO and split-op PPO loops), and
jobs ARRIVE and LEAVE against the live service: a GRPO job starts, a PPO
job attaches mid-flight on a fresh node group (its dispatch worker spawns
dynamically), a third job detaches with work still queued — queued ops
cancel, in-flight ops resolve, and billing stays incremental throughout.

Part 4 (auto-placement + autoscale, §4.3-§4.4): jobs are added with
`group_id=None`, so the cluster CONTROL PLANE decides where they run. Each
arrival is cold-placed on a dedicated profiling group (spawned on demand),
the online profiler folds the executor's per-op task records into its
JobTrace, and after the warmup cycle the job is re-fitted by micro-shift
trace fitting — live-migrating onto a shared group (admission hold ->
in-flight drain -> StateManager.migrate -> queued-op rehome) while the
drained profiling group is retired. A later arrival finds no clean group
and triggers a capacity-adjustment spawn. The director's decision log
prints at the end.

Part 5 (continuous reconciliation, §4.3.2's repacking loop — scripted on a
VirtualClock so every decision is deterministic): two jobs consolidate onto
one group, then one job's ROLLOUT PHASE DOUBLES mid-run (response lengths
grow as the policy improves). The reconciler compares the rolling profile
against the placed trace, detects the drift, re-profiles, re-fits — the
grown cycle no longer coexists with its neighbour — spawns a group and
live-migrates, with the whole detect -> re-profile -> repack -> migrate
sequence in the director's decision log.

Part 6 (multi-tenant service layer): the plane serves two TENANTS — a
GUARANTEED "prod" tenant with an SLO and a BEST_EFFORT "lab" tenant with a
1-job group quota. A second lab submission is admission-QUEUED at quota
(typed denial, not a stack trace), the operator TIGHTENS prod's SLO
mid-serve (re-registering the spec), the next folded steps breach the
rolling p95 and the director's fourth trigger preempts/holds the
best-effort job, and detaching the first lab job drains the queued one in.
Decision log and per-tenant accounting print at the end.

Run:  PYTHONPATH=src python examples/multiplex_rlvr.py
"""
import time

import numpy as np

from repro.core import api, tenancy
from repro.core.cluster import PlexCluster
from repro.core.control_plane import DirectorConfig, PlacementDirector
from repro.core.controller import JobConfig
from repro.core.router import Router
from repro.core.scheduler.executor import VirtualClock

TINY = (("num_layers", 2), ("d_model", 48), ("num_heads", 4),
        ("num_kv_heads", 2), ("head_dim", 12), ("d_ff", 96),
        ("vocab_size", 64), ("tie_embeddings", True), ("attn_q_chunk", 32))


def make_jobs():
    return [
        JobConfig(job_id="alpha", model_name="qwen2-0.5b", steps=3,
                  batch_size=8, group_size=4, max_new_tokens=6, seq_len=32,
                  overrides=TINY, seed=1),
        JobConfig(job_id="beta", model_name="qwen2-0.5b", steps=3,
                  batch_size=8, group_size=4, max_new_tokens=6, seq_len=32,
                  overrides=TINY, seed=2),
    ]


def wait_until(cluster, cond, timeout: float = 300.0):
    """Poll a serve-mode condition, failing fast if a client thread died
    (otherwise its error would only surface at serve() exit)."""
    t0 = time.time()
    while not cond():
        if cluster.client_errors:
            job, err = next(iter(cluster.client_errors.items()))
            raise RuntimeError(f"job {job!r} client thread failed: "
                               f"{err!r}") from err
        if time.time() - t0 > timeout:
            raise TimeoutError("serve-mode job made no progress")
        time.sleep(0.05)


def run(interleave: bool, n_groups: int = 1, concurrent: bool = False):
    cluster = PlexCluster(n_groups=n_groups)
    for g, cfg in enumerate(make_jobs()):
        cluster.add_job(cfg, group_id=g % n_groups)
    t0 = time.time()
    billing = cluster.run(interleave=interleave, concurrent=concurrent)
    wall = time.time() - t0
    return cluster, billing, wall


def part5_drift_reconciliation():
    """Scripted VirtualClock demo of the reconciliation loop: jobA's
    rollout doubles mid-run; the phase-drift trigger re-profiles, re-fits,
    and live-migrates it, and the decision log shows every step."""
    clock = VirtualClock()

    class ScriptedWPG:
        """Stub backend on the virtual clock: each op advances time by its
        exec_estimate, so drift is scripted rather than measured."""

        def __init__(self, spec, sm):
            self.spec, self.sm, self.exec_log = spec, sm, []

        @property
        def job_prefix(self):
            return f"{self.spec.job_id}:{self.spec.deployment_id}"

        def resident(self):
            return False

        def ensure_resident(self):
            return 0.0

        def offload(self, to=None):
            return 0.0

        def execute(self, qop):
            clock.advance(qop.exec_estimate)
            self.exec_log.append((qop.op.value, qop.exec_estimate))
            return None

    router = Router(now=clock, wpg_factory=ScriptedWPG)
    director = PlacementDirector(
        router, DirectorConfig(horizon=300.0, cold_reserve_s=40.0,
                               min_groups=1, warmup_cycles=0,
                               drift_window=2, drift_ratio=1.8),
        initial_groups=[0])
    deps = {}
    for job in ("epsilon", "zeta"):
        gid = director.assign(job)
        spec = api.DeploymentSpec(deployment_id=f"{job}-train", job_id=job,
                                  model_name="stub", role="train")
        deps[job] = router.deploy(spec, group_id=gid)

    def run_cycle(job, phases):
        prev, d = None, deps[job]
        for op, dur in phases:
            fn = getattr(d, op)
            args = ((np.zeros((1, 2), np.int32),) if op == "generate"
                    else (d,) if op == "sync_weights" else (0,))
            prev = fn(*args, exec_estimate=dur,
                      after=(prev,) if prev else ())
        router.drain()
        prev.result()
        director.on_job_step(job)

    for step in range(6):
        rollout = 6.0 if step < 2 else 12.0     # epsilon's rollout DOUBLES
        run_cycle("epsilon", [("generate", rollout),
                              ("update_actor", 2.0 if step < 2 else 3.5)])
        run_cycle("zeta", [("generate", 1.0), ("forward", 2.0),
                           ("update_actor", 2.0), ("sync_weights", 1.0)])
        clock.advance(0.25)
    print("control-plane decision log (virtual time):")
    for e in director.events:
        print("  ", {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in e.items()})
    plan = director.cluster_plan()
    for a in plan.assignments:
        print(f"{a.job_id}: group={a.group_id} shift={a.shift:.2f} "
              f"period={a.trace.period:.1f}s (plan v{plan.version})")


def part6_multi_tenant_service():
    """Two tenants against one live plane: quota-queued admission, an SLO
    tightened mid-serve, and the director's SLO-guarded preemption trigger
    defending the guaranteed tenant — decision log printed."""
    cluster = PlexCluster(
        n_groups=1,
        # cooldown off: the consolidation migrate would otherwise pin the
        # best-effort job against preemption for 30s of this short demo
        director_cfg=DirectorConfig(warmup_cycles=0, max_groups=3,
                                    repack_interval_s=1e9,
                                    migration_cooldown_s=0.0,
                                    slo_window=6, slo_min_samples=3))
    # prod: GUARANTEED with a deliberately loose SLO for now (tightened
    # live below); lab: BEST_EFFORT, low priority, at most ONE job admitted
    cluster.register_tenant(tenancy.TenantSpec(
        "prod", priority=4.0, class_=tenancy.TenantClass.GUARANTEED,
        slo_step_latency_s=1e9))
    cluster.register_tenant(tenancy.TenantSpec(
        "lab", priority=0.5, quota_groups=1))

    def tenant_job(job_id, tenant, steps, seed):
        return JobConfig(job_id=job_id, model_name="qwen2-0.5b",
                         steps=steps, batch_size=8, group_size=4,
                         max_new_tokens=6, seq_len=32, overrides=TINY,
                         seed=seed, tenant=tenant)

    with cluster.serve():
        cluster.add_job(tenant_job("prod-1", "prod", 10, 1), group_id=None)
        cluster.add_job(tenant_job("lab-1", "lab", 60, 2), group_id=None)
        # the greedy tenant tries to attach a SECOND job: at quota it is
        # a typed denial, and with queue_on_deny it parks instead
        try:
            cluster.add_job(tenant_job("lab-2", "lab", 2, 3), group_id=None)
        except tenancy.AdmissionDenied as denied:
            print(f"lab-2 denied: {denied}")
        cluster.add_job(tenant_job("lab-2", "lab", 2, 3), group_id=None,
                        queue_on_deny=True)
        depth = cluster.router.tenant_telemetry()["lab"]["pending_jobs"]
        print(f"lab-2 admission-queued (lab pending depth: {depth})")
        # wait until prod's rolling p95 is meaningful, then TIGHTEN the
        # SLO below it: the next folded steps breach and trigger 4 fires
        wait_until(cluster, lambda: cluster.tenant_ledger.snapshot()
                   .get("prod", {}).get("step_p95_s") is not None)
        p95 = cluster.tenant_ledger.snapshot()["prod"]["step_p95_s"]
        cluster.register_tenant(tenancy.TenantSpec(
            "prod", priority=4.0, class_=tenancy.TenantClass.GUARANTEED,
            slo_step_latency_s=p95 / 2))
        print(f"prod SLO tightened mid-serve: {p95:.2f}s p95 -> "
              f"{p95 / 2:.2f}s objective")
        wait_until(cluster, lambda: any(
            e["event"] in ("slo_preempt", "slo_hold")
            for e in cluster.director.events))
        # the first lab job leaves: its quota frees and the QUEUED lab-2
        # is admitted automatically by the drain
        cluster.remove_job("lab-1")
        wait_until(cluster, lambda: "lab-2" in cluster.controllers)
        print("lab-1 detached -> queued lab-2 admitted "
              f"(lab active: {cluster.admission.active_count('lab')})")
    print("tenancy decision log:")
    for e in cluster.director.events:
        if e["event"].startswith("slo_") or e["event"] == "spawn_group":
            print("  ", {k: (round(v, 3) if isinstance(v, float) else v)
                         for k, v in e.items()})
    print("per-tenant accounting (Router.tenant_telemetry):")
    for tenant, tel in sorted(cluster.router.tenant_telemetry().items()):
        att = tel.get("slo_attainment")
        print(f"  {tenant}: jobs={tel['jobs']} "
              f"gpu_s={tel.get('gpu_seconds', 0.0):.1f} "
              f"steps={tel.get('steps_total', 0)} "
              f"slo_attainment={att if att is None else round(att, 3)} "
              f"p95={tel.get('step_p95_s')}")


def main():
    print("=== Part 1: one shared group (HRRS multiplexing) ===")
    print("--- isolated (back-to-back) ---")
    c1, b1, w1 = run(interleave=False)
    print(f"wall {w1:.1f}s; switches={len(c1.router.switch_log)}")

    print("--- PlexRL multiplexed ---")
    c2, b2, w2 = run(interleave=True)
    print(f"wall {w2:.1f}s; switches={len(c2.router.switch_log)}")

    for job in ("alpha", "beta"):
        print(f"{job}: billed gpu_s/step isolated="
              f"{b1[job].gpu_seconds_per_step():.2f} "
              f"multiplexed={b2[job].gpu_seconds_per_step():.2f} "
              f"(switch overhead {b2[job].switch_seconds:.3f}s)")
        r = c2.controllers[job].reward_log
        print(f"{job}: rewards {np.round(r, 3).tolist()}")

    print("\n=== Part 2: two groups (concurrent dispatch plane) ===")
    print("--- serial driver (ops execute inline, no overlap) ---")
    _, _, w3 = run(interleave=True, n_groups=2, concurrent=False)
    print(f"wall {w3:.1f}s")

    print("--- concurrent driver (one dispatch thread per group) ---")
    _, _, w4 = run(interleave=True, n_groups=2, concurrent=True)
    print(f"wall {w4:.1f}s -> serial/concurrent ratio "
          f"{w3 / max(w4, 1e-9):.2f}x")

    print("\n=== Part 3: serve mode (jobs attach/detach against a live "
          "plane) ===")
    jobs = make_jobs()
    cluster = PlexCluster(n_groups=1)
    cluster.add_job(jobs[0], group_id=0)              # GRPO, pre-registered
    t0 = time.time()
    with cluster.serve():
        # wait for the first job to make progress, then attach a PPO job
        # on a NEW group while the plane is live
        wait_until(cluster,
                   lambda: cluster.controllers["alpha"].reward_log)
        cluster.add_job(jobs[1], group_id=1, algo="ppo")
        # and a job that leaves early: detach cancels its queued ops,
        # resolves its in-flight ones, and keeps its bill
        doomed = JobConfig(job_id="gamma", model_name="qwen2-0.5b",
                           steps=50, batch_size=8, group_size=4,
                           max_new_tokens=6, seq_len=32, overrides=TINY,
                           seed=3)
        cluster.add_job(doomed, group_id=0)
        wait_until(cluster,
                   lambda: cluster.controllers["gamma"].steps_completed)
        cluster.remove_job("gamma")
        print("gamma detached after "
              f"{cluster.controllers['gamma'].steps_completed} step(s)")
    print(f"serve wall {time.time() - t0:.1f}s")
    for job in ("alpha", "beta", "gamma"):
        rec = cluster.billing[job]
        print(f"{job}: steps={rec.steps} billed "
              f"gpu_s/step={rec.gpu_seconds_per_step():.2f}")

    print("\n=== Part 4: auto-placement + autoscale (the control plane) ===")
    cluster = PlexCluster(n_groups=1)
    t0 = time.time()
    jobs = make_jobs()
    with cluster.serve():
        # group_id=None routes each arrival through the PlacementDirector:
        # cold profiling group -> online JobTrace -> micro-shift warm fit
        # (+ live migration onto the shared group)
        cluster.add_job(jobs[0], group_id=None)
        cluster.add_job(jobs[1], group_id=None)
        wait_until(cluster, lambda: all(
            cluster.director.job_state(j) is not None
            and cluster.director.job_state(j).phase == "warm"
            for j in ("alpha", "beta")))
        # a late arrival finds no clean profiling group: capacity spawn
        late = JobConfig(job_id="delta", model_name="qwen2-0.5b", steps=2,
                         batch_size=8, group_size=4, max_new_tokens=6,
                         seq_len=32, overrides=TINY, seed=4)
        cluster.add_job(late, group_id=None)
    print(f"serve wall {time.time() - t0:.1f}s; control-plane decisions:")
    for e in cluster.director.events:
        print("  ", {k: (round(v, 3) if isinstance(v, float) else v)
                     for k, v in e.items()})
    for job in ("alpha", "beta", "delta"):
        js = cluster.director.job_state(job)
        rec = cluster.billing[job]
        print(f"{job}: phase={js.phase} group={js.group_id} "
              f"steps={rec.steps} billed "
              f"gpu_s/step={rec.gpu_seconds_per_step():.2f}")

    print("\n=== Part 5: continuous reconciliation (drift -> re-profile -> "
          "repack -> migrate) ===")
    part5_drift_reconciliation()

    print("\n=== Part 6: multi-tenant service layer (quotas, SLO-guarded "
          "preemption) ===")
    part6_multi_tenant_service()

    print("\nNOTE: on one CPU every op is compute-bound and XLA already"
          "\nsaturates all cores, so neither HRRS (Part 1) nor cross-group"
          "\noverlap (Part 2) can reclaim idle time HERE — both parts are"
          "\nMECHANISM demonstrations: HRRS-batched context switches,"
          "\nmeasured setup costs, per-job billing, and group dispatch on"
          "\nindependent worker threads. tests/test_dispatch.py pins the"
          "\noverlap guarantee (<0.9x serial wall-clock on two groups) with"
          "\nGIL-releasing ops; the capacity gain at cluster scale is"
          "\nquantified by benchmarks/fig8_policies.py (1.8x) and"
          "\nfig7_cost.py (31-38 %).")


if __name__ == "__main__":
    main()
