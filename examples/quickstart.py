"""Quickstart: the PlexRL public API in ~80 lines.

1. Build a model from the registry and run a GRPO train step directly.
2. Stand the same thing up as a serviceized deployment behind the Router
   and program it through the dataflow client API (the paper's §4.2
   interface): a bound ``Deployment`` handle whose methods return chainable
   futures — ``.then(fn)`` interposes client-side transforms, and passing a
   future as the next op's argument is the dependency edge (the scheduler
   gates admission on it and splices the value in at dispatch).
3. The same chain against a live ``serve()`` plane: submit from client
   code while dispatch workers run persistently in the background.

(`api.make_op` + `router.submit_queued_operation` remain underneath as the
low-level escape hatch: explicit req_id prerequisites, custom arrival
times. Normal algorithm code never needs them.)

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec, get_config, reduced_config
from repro.models.registry import build_model
from repro.rl import grpo
from repro.train import train_state as ts

# ---------------------------------------------------------------- 1. direct
cfg = reduced_config("qwen3-4b")          # same family, tiny dims (CPU demo)
model = build_model(cfg)
print(f"model {cfg.name}: {model.param_count():,} params (reduced)")

state = ts.init(model, jax.random.PRNGKey(0))
batch = model.dummy_batch(jax.random.PRNGKey(1), ShapeSpec("t", "train", 32, 8))
step = jax.jit(grpo.make_update_actor(model))
state, metrics = step(state, batch)
print("one update_actor:", {k: round(float(v), 4) for k, v in metrics.items()})

# ------------------------------------------------------------- 2. serviceized
from repro.core import api
from repro.core.router import Router

router = Router()
spec = api.DeploymentSpec(
    deployment_id="demo-train", job_id="demo", model_name="qwen3-4b",
    role="train",
    overrides=tuple({"num_layers": 2, "d_model": 64, "num_heads": 4,
                     "num_kv_heads": 2, "head_dim": 16, "d_ff": 128,
                     "vocab_size": 128, "attn_q_chunk": 32}.items()))
dep = router.deploy(spec, group_id=0)     # bound client handle

init_f = dep.init(seed=0)
prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 3, 128)
# the dataflow chain: init gates generate through `after=` (pure ordering),
# and `.then` interposes a client-side transform on the rollout result; a
# future passed as a later op's ARGUMENT would add the prerequisite edge
# and dispatch-time value splice automatically (the controllers do exactly
# that with their packed train batches)
gen_f = dep.generate(prompts, max_new_tokens=8, after=(init_f,))
count_f = gen_f.then(lambda g: int((jnp.asarray(g["tokens"]) > 0).sum()))
router.drain()                            # the scheduler admits + executes
gen = gen_f.result()
print("generated:", gen["tokens"].shape, "logprobs:", gen["logprobs"].shape,
      "non-pad tokens:", count_f.result())
print("state manager usage:", router.state_managers[0].usage())

# ------------------------------------------------------- 3. serve-mode plane
# The same chain against the PERSISTENT dispatch plane: workers park on the
# scheduler's condition variable while idle, admit the moment work arrives,
# and the client just blocks on futures. Jobs can attach and detach while
# the plane is live (see examples/multiplex_rlvr.py Part 3).
with router:                              # serve() ... shutdown()
    gen2 = dep.generate(prompts, max_new_tokens=8).wait(timeout=120)
print("serve-mode generate:", gen2["tokens"].shape)

# --------------------------------------------- 4. automatic placement (jobs)
# At the JOB level placement itself is a service decision. The contract:
#
#     cluster = PlexCluster(n_groups=1)
#     with cluster.serve():
#         cluster.add_job(cfg, group_id=None)    # <- the control plane picks
#
# ``group_id=None`` routes the arrival through the cluster control plane
# (core/control_plane.py): the job is COLD-placed on a dedicated profiling
# group (spawned on demand), its phase durations are profiled online from
# the executor's task records, and after the warmup cycle it is re-fitted by
# micro-shift trace fitting and LIVE-MIGRATED onto a shared group; capacity
# adjustment spawns/retires groups from queue-depth telemetry, and
# `cluster.director.events` is the audit log of every decision. Passing an
# explicit ``group_id`` pins the job and bypasses the director entirely.
# See examples/multiplex_rlvr.py Part 4 for the full flow.
