"""Quickstart: the PlexRL public API in ~60 lines.

1. Build a model from the registry and run a GRPO train step directly.
2. Stand the same thing up as a serviceized deployment behind the Router
   and drive it with queued operations (the paper's §4.2 interface).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ShapeSpec, get_config, reduced_config
from repro.models.registry import build_model
from repro.rl import grpo
from repro.train import train_state as ts

# ---------------------------------------------------------------- 1. direct
cfg = reduced_config("qwen3-4b")          # same family, tiny dims (CPU demo)
model = build_model(cfg)
print(f"model {cfg.name}: {model.param_count():,} params (reduced)")

state = ts.init(model, jax.random.PRNGKey(0))
batch = model.dummy_batch(jax.random.PRNGKey(1), ShapeSpec("t", "train", 32, 8))
step = jax.jit(grpo.make_update_actor(model))
state, metrics = step(state, batch)
print("one update_actor:", {k: round(float(v), 4) for k, v in metrics.items()})

# ------------------------------------------------------------- 2. serviceized
from repro.core import api
from repro.core.router import Router

router = Router()
spec = api.DeploymentSpec(
    deployment_id="demo-train", job_id="demo", model_name="qwen3-4b",
    role="train",
    overrides=tuple({"num_layers": 2, "d_model": 64, "num_heads": 4,
                     "num_kv_heads": 2, "head_dim": 16, "d_ff": 128,
                     "vocab_size": 128, "attn_q_chunk": 32}.items()))
router.create_deployment(spec, group_id=0)

fut_init = router.submit_queued_operation(api.make_op(spec, api.Op.INIT, 0))
prompts = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 3, 128)
fut_gen = router.submit_queued_operation(
    api.make_op(spec, api.Op.GENERATE, prompts, max_new_tokens=8,
                prerequisites=(fut_init,) and ()))
router.drain()                            # the scheduler admits + executes
gen = fut_gen.result()
print("generated:", gen["tokens"].shape, "logprobs:", gen["logprobs"].shape)
print("state manager usage:", router.state_managers[0].usage())
