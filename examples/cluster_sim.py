"""Cluster-scale what-if: replay a 3-month-style job mix through the four
scheduling policies and print the Fig. 8 numbers (delay CDF percentiles,
makespan ratio, effective capacity gain).

Run:  PYTHONPATH=src python examples/cluster_sim.py [--jobs 64] [--nodes 32]
"""
import argparse

import numpy as np

from repro.core.simulator import run_policy_comparison
from repro.core.traces import synthetic_job_mix


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=48)
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--nodes", type=int, default=32)
    ap.add_argument("--group-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=11)
    args = ap.parse_args(argv)

    profiles = synthetic_job_mix(args.jobs, seed=args.seed)
    res = run_policy_comparison(
        profiles, steps=args.steps, arrival_rate=1 / 90.0, seed=args.seed,
        total_nodes=args.nodes, group_size=args.group_size)

    iso = res["isolated"].makespan
    print(f"{'policy':18s} {'p50':>8s} {'p90':>8s} {'p99':>8s} "
          f"{'makespan':>10s} {'vs iso':>7s} {'util':>6s}")
    for pol, r in res.items():
        d = r.norm_delays()
        print(f"{pol:18s} {np.percentile(d, 50):8.3f} "
              f"{np.percentile(d, 90):8.3f} {np.percentile(d, 99):8.3f} "
              f"{r.makespan:9.0f}s {r.makespan / iso:7.2%} "
              f"{r.utilization():6.1%}")
    sb = res["spread_backfill"]
    print(f"\neffective capacity gain (iso makespan / spread+backfill): "
          f"{iso / sb.makespan:.2f}x   (paper: ~1.8x)")


if __name__ == "__main__":
    main()
