"""End-to-end driver: train a ~100M-param qwen2-family model with GRPO on
the synthetic verifiable-math task for a few hundred steps, through the full
PlexRL service stack, with periodic checkpointing and restart-on-failure.

Run:  PYTHONPATH=src python examples/train_100m.py [--steps 200]
(CPU: ~100M params is slow; --steps 20 for a quick pass. The driver is the
same one a pod run would use: repro.launch.train.)
"""
import argparse

from repro.launch import train as train_driver


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args(argv)
    # ~100M params: 12 layers x d_model 640 x vocab 4096
    train_driver.main([
        "--arch", "qwen2-0.5b",
        "--steps", str(args.steps),
        "--layers", "12",
        "--d-model", "640",
        "--vocab", "4096",
        "--batch-size", "16",
        "--group-size", "4",
        "--max-new-tokens", "24",
        "--seq-len", "96",
        "--ckpt-dir", "/tmp/plexrl_100m",
        "--ckpt-every", "50",
    ])


if __name__ == "__main__":
    main()
